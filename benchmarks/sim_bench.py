"""Simulator parity benchmarks: measured theta vs the analytic tables.

Each row replays one (topology, pattern, routing) through repro.sim's
saturation sweep and compares the measured knee against the fluid model:

* ``parity`` rows run in the fluid limit (zero threshold, infinite
  buffers) where the simulator must reproduce the registry theta —
  ``max_rel_err`` is the relative gap vs the matching analytic model
  (minimal / valiant / the exact ugal blend).  The headline acceptance
  row is pn16 uniform: measured theta within 5% of Eq. 1's a = Δ·u/k̄.
* ``band`` rows exercise what the closed form cannot price — a positive
  threshold, finite buffers, or an adversary whose ideal blend is full
  Valiant (local state cannot see the remote detour congestion, so
  threshold-UGAL lands strictly inside the bracket).  ``max_rel_err`` is
  the band violation: how far measured theta falls below theta_minimal
  or above theta_ugal.  The acceptance row is the 8x16-torus tornado:
  threshold-UGAL between theta_minimal and theta_ugal.

``benchmarks.run --only sim`` serializes the table into BENCH_5.json and
exits nonzero when any row exceeds ``--err-budget`` (fail-loud parity).

Row budgets (loads bracket, steps, refine) are tuned so the whole table
fits CI_SIM_BUDGET: probes bracket the knee at ~±6% and bisection
tightens the stable side to ~2%.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import demi_pn_graph, oft_graph, pn_graph
from repro.core.traffic import saturation_report
from repro.fabric.model import torus3d_graph
from repro.sim import SimConfig, fluid_routing_spec, saturation_sweep


@dataclass
class SimCase:
    name: str
    graph_fn: object = field(repr=False)
    pattern: str = "uniform"
    routing: str = "minimal"
    kind: str = "parity"            # parity | band
    buffer: float = float("inf")
    loads: tuple = (0.90, 1.06)     # fractions of the analytic reference
    steps: int = 320
    refine: int = 2


SIM_CASES = [
    # fluid-limit parity: the acceptance row (pn16 uniform within 5%)
    SimCase("pn16:uniform:minimal", lambda: pn_graph(16),
            "uniform", "minimal", loads=(0.95, 1.06), steps=48),
    SimCase("pn16:uniform:ugal0", lambda: pn_graph(16),
            "uniform", "ugal_threshold(0)", loads=(0.97, 1.08), steps=40,
            refine=1),
    SimCase("demi_pn16:uniform:minimal", lambda: demi_pn_graph(16),
            "uniform", "minimal", steps=64),
    SimCase("oft4:uniform:ugal0", lambda: oft_graph(4),
            "uniform", "ugal_threshold(0)", steps=96),
    SimCase("torus2d_8x16:uniform:minimal", lambda: torus3d_graph(8, 16, 1),
            "uniform", "minimal"),
    SimCase("torus2d_8x16:tornado:minimal", lambda: torus3d_graph(8, 16, 1),
            "tornado", "minimal"),
    SimCase("torus2d_8x16:tornado:valiant", lambda: torus3d_graph(8, 16, 1),
            "tornado", "valiant"),
    # the acceptance band row: threshold-UGAL on tornado's home ground
    # lands between theta_minimal and theta_ugal (and in the fluid limit
    # reproduces the blend, so it is also held to parity)
    SimCase("torus2d_8x16:tornado:ugal0", lambda: torus3d_graph(8, 16, 1),
            "tornado", "ugal_threshold(0)", kind="both", refine=3),
    # beyond the closed form: a positive margin (theta unchanged, only
    # the diversion onset moves), finite buffers (backpressure), and an
    # adversary whose ideal blend is full Valiant (local state lands
    # strictly inside the bracket)
    SimCase("torus2d_8x16:tornado:ugal2", lambda: torus3d_graph(8, 16, 1),
            "tornado", "ugal_threshold(2)", kind="band", refine=3),
    SimCase("torus2d_8x16:tornado:ugal0:buf8", lambda: torus3d_graph(8, 16, 1),
            "tornado", "ugal_threshold(0)", kind="band", buffer=8.0),
    SimCase("demi_pn16:tornado:ugal0", lambda: demi_pn_graph(16),
            "tornado", "ugal_threshold(0)", kind="band", steps=64),
]


def sim_cases():
    return [(c.name, c) for c in SIM_CASES]


def sim_one(case: SimCase) -> tuple[dict, float]:
    """Run one row; returns ``(row, max_rel_err)``.

    ``row`` records the measured theta/bracket/alpha plus the analytic
    minimal / ugal / reference thetas; ``max_rel_err`` is the parity gap
    (parity rows), the band violation (band rows), or the max of both."""
    g = case.graph_fn()
    cfg = SimConfig(routing=case.routing, buffer=case.buffer)
    fluid = fluid_routing_spec(case.routing)
    ref = saturation_report(g, case.pattern, routing=fluid)
    sweep = saturation_sweep(
        g, case.pattern, routing=case.routing,
        loads=np.asarray(case.loads) * ref.theta,
        steps=case.steps, refine=case.refine, config=cfg,
        theta_analytic=ref.theta)
    th_min = (ref.theta if fluid == "minimal" else
              saturation_report(g, case.pattern, routing="minimal").theta)
    th_ugal = (ref.theta if fluid == "ugal" else
               saturation_report(g, case.pattern, routing="ugal").theta)

    parity = abs(sweep.theta - sweep.theta_analytic) / sweep.theta_analytic
    lo, hi = min(th_min, th_ugal), max(th_min, th_ugal)
    band = max(0.0, (lo - sweep.theta) / lo, (sweep.theta - hi) / hi)
    err = {"parity": parity, "band": band,
           "both": max(parity, band)}[case.kind]

    stable = [r for r in sweep.runs if r.offered <= sweep.theta * (1 + 1e-12)]
    alpha = stable[-1].alpha if stable else float("nan")
    row = {
        "case": case.name, "pattern": sweep.pattern,
        "routing": case.routing, "kind": case.kind,
        "buffer": None if np.isinf(case.buffer) else case.buffer,
        "theta_sim": sweep.theta,
        "theta_unstable": (None if not np.isfinite(sweep.theta_unstable)
                           else sweep.theta_unstable),
        "theta_analytic": sweep.theta_analytic,
        "theta_minimal": th_min, "theta_ugal": th_ugal,
        "alpha_sim": alpha, "parity_err": parity, "band_err": band,
        "steps": case.steps, "backend": sweep.runs[0].backend,
    }
    return row, err
