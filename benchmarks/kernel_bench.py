"""Fused sparse-destination step kernel benchmarks (BENCH_7).

Three rows pin the PR's kernel seam (repro.sim.kernel / repro.kernels):

* ``step_timing`` — per-step wall time of the pn16 uniform step on every
  backend (dense numpy float64 oracle, dense jax, fused blocked
  ``pallas``), plus the delivered-history parity of the fused backend in
  its production dtype (float32) against the oracle.
* ``pn16_sweep`` — the acceptance row: the BENCH_5 headline case
  (pn16 uniform ugal_threshold(0) saturation sweep) on the fused
  backend.  ``max_rel_err`` is the knee's parity vs analytic theta;
  ``speedup`` is wall-clock vs the dense-backend BENCH_5 row (read from
  BENCH_5.json when present, else the recorded CI-machine baseline).
* ``pn27_sweep`` — the beyond-the-cap row: PN(27) (1514 routers, 64.2M
  dense cells > SIM_MAX_CELLS, where every dense backend refuses) swept
  end-to-end via backend auto -> pallas with static dest compaction.
  The demand is all sources -> the point partition: the collineation
  group is transitive on points and flag-transitive on incidences, so
  every point column (and every point->line arc) is equivalent —
  saturation collapses globally and the measured knee is sharp enough
  to hold against the analytic theta.  (A random dest subset is NOT:
  its one bottleneck link carries a vanishing share of the aggregate
  delivered/offered ratio, so the 0.98-stable knee overshoots by ~10%
  on *every* backend — a measurement property, not a kernel one.)

``benchmarks.run --only kernels`` serializes the table into BENCH_7.json
and exits nonzero when any row's parity exceeds ``--err-budget``
(scripts/ci.sh passes 0.025, the ISSUE's 2.5% acceptance bound).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import pn_graph
from repro.core.traffic import make_pattern, normalize_demand, saturation_report
from repro.sim import SIM_MAX_CELLS, SimConfig, Simulator, saturation_sweep

# BENCH_5's sim[pn16:uniform:ugal0] wall time on the CI machine — the
# dense-backend baseline the fused sweep is held to 10x against.  The
# live BENCH_5.json value supersedes this when the artifact is present.
BASELINE_PN16_UGAL0_SECONDS = 100.78


def _bench5_baseline() -> tuple[float, str]:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_5.json")
    try:
        with open(path) as fh:
            for e in json.load(fh)["entries"]:
                if e["name"] == "sim[pn16:uniform:ugal0]":
                    return float(e["seconds"]), "BENCH_5.json"
    except (OSError, KeyError, ValueError):
        pass
    return BASELINE_PN16_UGAL0_SECONDS, "recorded"


def _points_demand(q: int):
    """All sources -> every point of PG(2, q): the transitive-orbit
    demand whose saturation knee is globally sharp (module docstring)."""
    g = pn_graph(q)
    npts = q * q + q + 1
    dem = np.zeros((g.n, g.n))
    dem[:, :npts] = 1.0
    np.fill_diagonal(dem, 0.0)
    return g, normalize_demand(dem)


def step_timing(steps: int = 24, offered: float = 0.5) -> tuple[dict, float]:
    """Per-step wall time per backend + fused-vs-oracle parity."""
    g = pn_graph(16)
    dem = normalize_demand(make_pattern("uniform").demand(g, None))
    ms = {}
    hist = {}
    for backend in ("numpy", "jax", "pallas"):
        sim = Simulator(g, SimConfig(routing="ugal_threshold(0)",
                                     backend=backend), demand=dem)
        sim.run(dem, offered, 2)  # warm the jit/tables caches
        t0 = time.perf_counter()
        r = sim.run(dem, offered, steps)
        ms[backend] = (time.perf_counter() - t0) / steps * 1e3
        hist[backend] = r.history["delivered"]
    ref = hist["numpy"]
    scale = max(float(np.abs(ref).max()), 1e-30)
    parity = float(np.abs(hist["pallas"] - ref).max() / scale)
    row = {"case": "pn16:uniform:ugal0:step", "steps": steps,
           "ms_per_step": {k: round(v, 3) for k, v in ms.items()},
           "parity_err": parity}
    return row, parity


def pn16_sweep() -> tuple[dict, float]:
    """The BENCH_5 headline sweep on the fused backend, timed against
    the dense baseline."""
    g = pn_graph(16)
    cfg = SimConfig(routing="ugal_threshold(0)", backend="pallas")
    ref = saturation_report(g, "uniform", routing="ugal")
    t0 = time.perf_counter()
    sweep = saturation_sweep(g, "uniform", routing="ugal_threshold(0)",
                             loads=np.array([0.97, 1.08]) * ref.theta,
                             steps=40, refine=2, config=cfg,
                             theta_analytic=ref.theta)
    seconds = time.perf_counter() - t0
    baseline, src = _bench5_baseline()
    parity = abs(sweep.theta - ref.theta) / ref.theta
    row = {"case": "pn16:uniform:ugal0", "backend": "pallas",
           "theta_sim": sweep.theta, "theta_analytic": ref.theta,
           "parity_err": parity, "seconds": round(seconds, 3),
           "baseline_seconds": baseline, "baseline_source": src,
           "speedup": round(baseline / seconds, 2)}
    return row, parity


def pn27_sweep() -> tuple[dict, float]:
    """PN(27) past the dense cap: auto -> pallas + dest compaction."""
    g, dem = _points_demand(27)
    cells = g.n * g.max_degree * g.n
    assert cells > SIM_MAX_CELLS  # the row exists to cross the cap
    ref = saturation_report(g, dem, routing="minimal")
    cfg = SimConfig(routing="minimal")  # backend=auto
    sim = Simulator(g, cfg, demand=dem)
    t0 = time.perf_counter()
    sweep = saturation_sweep(g, dem, routing="minimal", config=cfg,
                             loads=np.array([0.90, 1.08]) * ref.theta,
                             steps=40, refine=2, theta_analytic=ref.theta)
    seconds = time.perf_counter() - t0
    parity = abs(sweep.theta - ref.theta) / ref.theta
    row = {"case": "pn27:points:minimal", "backend": sim.backend,
           "routers": g.n, "dense_cells": cells,
           "compacted_dests": len(sim.active),
           "theta_sim": sweep.theta, "theta_analytic": ref.theta,
           "parity_err": parity, "seconds": round(seconds, 3)}
    return row, parity
