"""Fused sparse-destination step kernel benchmarks (BENCH_7).

Five rows pin the kernel seam (repro.sim.kernel / repro.kernels):

* ``step_timing`` — per-step wall time of the pn16 uniform step on every
  backend (dense numpy float64 oracle, dense jax, fused blocked
  ``pallas``), plus the delivered-history parity of the fused backend in
  its production dtype (float32) against the oracle.
* ``pn16_sweep`` — the PR 7 acceptance row: the BENCH_5 headline case
  (pn16 uniform ugal_threshold(0) saturation sweep) on the fused
  backend.  ``max_rel_err`` is the knee's parity vs analytic theta;
  ``speedup`` is wall-clock vs the dense-backend BENCH_5 row (read from
  BENCH_5.json when present, else the recorded CI-machine baseline).
* ``pn16_ugal_compacted`` — the adaptive-compaction acceptance row: a
  24-column neighbor-fed demand swept under threshold-UGAL with the
  per-VC compacted dest axis (``compact="auto"``), then the SAME probe
  loads re-swept with ``compact="off"`` (the PR 7 all-columns path).
  Fails loud (err forced to 1.0) when the compacted sweep is not >= 3x
  faster.  The demand feeds each dest column only from its direct
  neighbors, so minimal routing is single-hop and perfectly
  ingress-balanced: NO routing scheme — analytic blend or per-flow
  adaptive — can beat the dest-ingress bound, and the measured knee
  must land on the analytic theta exactly.  (A scattered all-sources
  demand is NOT a parity case: per-flow UGAL genuinely sustains ~3-8%
  more than the best single-alpha blend when interior links bind, so
  the knee overshoots the analytic reference on every backend.)  The
  UGAL threshold is set high enough that over-capacity probes do not
  divert: diversion cannot add ingress capacity here, and suppressing
  the churn is precisely what the threshold is for.
* ``pn27_sweep`` — the beyond-the-cap minimal row: PN(27) (1514
  routers, 64.2M dense cells > SIM_MAX_CELLS) swept end-to-end on the
  fused backend with static dest compaction.  The backend is pinned to
  ``pallas``: since the active-set shrink now runs before backend
  selection, the post-shrink cell count (1514*28*757 ~ 32.1M) fits the
  dense guard and ``auto`` would resolve to jax.  The demand is all
  sources -> the point partition: the collineation group is transitive
  on points and flag-transitive on incidences, so every point column
  (and every point->line arc) is equivalent — saturation collapses
  globally and the measured knee is sharp enough to hold against the
  analytic theta.  (A random dest subset is NOT: its one bottleneck
  link carries a vanishing share of the aggregate delivered/offered
  ratio, so the 0.98-stable knee overshoots by ~10% on *every*
  backend — a measurement property, not a kernel one.)
* ``pn27_ugal`` — the compacted-adaptive-at-scale row: the same PN(27)
  points demand under ugal_threshold(0).  Adaptive routing keeps the
  full mid axis live (q1/stage2 spread over all 1514 routers), so no
  active-set shrink applies and the dense layout (64.2M cells) trips
  SIM_MAX_CELLS on every dense backend; ``auto`` escalates to pallas
  and the per-VC dest compaction (757 point columns) makes the sweep
  feasible end-to-end — impossible before the compacted pool.

``benchmarks.run --only kernels`` serializes the table into BENCH_7.json
and exits nonzero when any row's parity exceeds ``--err-budget``
(scripts/ci.sh passes 0.025, the ISSUE's 2.5% acceptance bound).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import pn_graph
from repro.core.traffic import make_pattern, normalize_demand, saturation_report
from repro.sim import SIM_MAX_CELLS, SimConfig, Simulator, saturation_sweep

# BENCH_5's sim[pn16:uniform:ugal0] wall time on the CI machine — the
# dense-backend baseline the fused sweep is held to 10x against.  The
# live BENCH_5.json value supersedes this when the artifact is present.
BASELINE_PN16_UGAL0_SECONDS = 100.78


def _bench5_baseline() -> tuple[float, str]:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_5.json")
    try:
        with open(path) as fh:
            for e in json.load(fh)["entries"]:
                if e["name"] == "sim[pn16:uniform:ugal0]":
                    return float(e["seconds"]), "BENCH_5.json"
    except (OSError, KeyError, ValueError):
        pass
    return BASELINE_PN16_UGAL0_SECONDS, "recorded"


def _points_demand(q: int):
    """All sources -> every point of PG(2, q): the transitive-orbit
    demand whose saturation knee is globally sharp (module docstring)."""
    g = pn_graph(q)
    npts = q * q + q + 1
    dem = np.zeros((g.n, g.n))
    dem[:, :npts] = 1.0
    np.fill_diagonal(dem, 0.0)
    return g, normalize_demand(dem)


def step_timing(steps: int = 24, offered: float = 0.5) -> tuple[dict, float]:
    """Per-step wall time per backend + fused-vs-oracle parity."""
    g = pn_graph(16)
    dem = normalize_demand(make_pattern("uniform").demand(g, None))
    ms = {}
    hist = {}
    for backend in ("numpy", "jax", "pallas"):
        sim = Simulator(g, SimConfig(routing="ugal_threshold(0)",
                                     backend=backend), demand=dem)
        sim.run(dem, offered, 2)  # warm the jit/tables caches
        t0 = time.perf_counter()
        r = sim.run(dem, offered, steps)
        ms[backend] = (time.perf_counter() - t0) / steps * 1e3
        hist[backend] = r.history["delivered"]
    ref = hist["numpy"]
    scale = max(float(np.abs(ref).max()), 1e-30)
    parity = float(np.abs(hist["pallas"] - ref).max() / scale)
    row = {"case": "pn16:uniform:ugal0:step", "steps": steps,
           "ms_per_step": {k: round(v, 3) for k, v in ms.items()},
           "parity_err": parity}
    return row, parity


def pn16_sweep() -> tuple[dict, float]:
    """The BENCH_5 headline sweep on the fused backend, timed against
    the dense baseline."""
    g = pn_graph(16)
    cfg = SimConfig(routing="ugal_threshold(0)", backend="pallas")
    ref = saturation_report(g, "uniform", routing="ugal")
    t0 = time.perf_counter()
    sweep = saturation_sweep(g, "uniform", routing="ugal_threshold(0)",
                             loads=np.array([0.97, 1.08]) * ref.theta,
                             steps=40, refine=2, config=cfg,
                             theta_analytic=ref.theta)
    seconds = time.perf_counter() - t0
    baseline, src = _bench5_baseline()
    parity = abs(sweep.theta - ref.theta) / ref.theta
    row = {"case": "pn16:uniform:ugal0", "backend": "pallas",
           "theta_sim": sweep.theta, "theta_analytic": ref.theta,
           "parity_err": parity, "seconds": round(seconds, 3),
           "baseline_seconds": baseline, "baseline_source": src,
           "speedup": round(baseline / seconds, 2)}
    return row, parity


def _neighbor_demand(q: int, n_cols: int, seed: int = 0):
    """``n_cols`` random dest columns, each fed equally by its direct
    neighbors only.  Minimal routing is single-hop and ingress-balanced,
    so the saturation knee is EXACTLY the analytic dest-ingress bound
    for every routing scheme (module docstring, pn16_ugal_compacted)."""
    g = pn_graph(q)
    rng = np.random.default_rng(seed)
    cols = np.sort(rng.choice(g.n, size=n_cols, replace=False))
    dem = np.zeros((g.n, g.n))
    for c in cols:
        dem[g.neighbors(c), c] = 1.0
    return g, normalize_demand(dem), cols


def pn16_ugal_compacted(n_cols: int = 24, steps: int = 40) -> tuple[dict, float]:
    """Compacted adaptive sweep vs the PR 7 all-columns path.

    Sweeps the neighbor-fed ``n_cols``-column demand under
    ugal_threshold(16) with the per-VC compacted dest axis and the
    per-dest knee criterion, then re-sweeps the SAME probe loads with
    ``compact="off"`` (refine=0 pins the probe set, so both paths do
    identical numerical work).  Err is knee parity vs the analytic
    blend — forced to 1.0 (fail-loud) when the speedup is < 3x."""
    g, dem, cols = _neighbor_demand(16, n_cols)
    ref = saturation_report(g, dem, routing="ugal")
    routing = "ugal_threshold(16)"
    cfg = SimConfig(routing=routing, backend="pallas")
    t0 = time.perf_counter()
    sweep = saturation_sweep(g, dem, routing=routing, config=cfg,
                             loads=np.array([0.96, 1.0, 1.05]) * ref.theta,
                             steps=steps, refine=3, stable_ratio=0.998,
                             theta_analytic=ref.theta, knee="per_dest")
    t_comp = time.perf_counter() - t0
    cfg_off = SimConfig(routing=routing, backend="pallas", compact="off")
    probe_loads = np.sort([r.offered for r in sweep.runs])
    t0 = time.perf_counter()
    saturation_sweep(g, dem, routing=routing, config=cfg_off,
                     loads=probe_loads, steps=steps, refine=0,
                     stable_ratio=0.998, theta_analytic=ref.theta,
                     knee="per_dest")
    t_off = time.perf_counter() - t0
    speedup = t_off / t_comp
    parity = abs(sweep.theta - ref.theta) / ref.theta
    err = parity if speedup >= 3.0 else max(parity, 1.0)
    row = {"case": f"pn16:nbr{n_cols}:ugal16", "backend": "pallas",
           "knee": "per_dest", "compacted_dests": int(len(cols)),
           "dense_dests": int(g.n),
           "theta_sim": sweep.theta, "theta_analytic": ref.theta,
           "parity_err": parity, "probes": len(sweep.runs),
           "seconds": round(t_comp, 3),
           "all_columns_seconds": round(t_off, 3),
           "speedup": round(speedup, 2)}
    return row, err


def pn27_ugal(steps: int = 30) -> tuple[dict, float]:
    """PN(27) adaptive sweep end-to-end — feasible only compacted.

    Under ugal the full mid axis stays live (no active-set shrink), so
    the dense layout trips SIM_MAX_CELLS and ``auto`` escalates to the
    fused backend; the per-VC dest compaction (757 point columns of
    1514) is what lets the sweep run at all (module docstring)."""
    g, dem = _points_demand(27)
    cells = g.n * g.max_degree * g.n
    assert cells > SIM_MAX_CELLS  # dense layout must be infeasible
    ref = saturation_report(g, dem, routing="ugal")
    cfg = SimConfig(routing="ugal_threshold(0)")  # backend=auto
    sim = Simulator(g, cfg, demand=dem)
    assert sim.backend == "pallas"
    t0 = time.perf_counter()
    sweep = saturation_sweep(g, dem, routing="ugal_threshold(0)",
                             config=cfg,
                             loads=np.array([0.95, 1.08]) * ref.theta,
                             steps=steps, refine=2,
                             theta_analytic=ref.theta)
    seconds = time.perf_counter() - t0
    parity = abs(sweep.theta - ref.theta) / ref.theta
    n_cols = len(sim.dest_cols) if sim.dest_cols is not None else g.n
    row = {"case": "pn27:points:ugal0", "backend": sim.backend,
           "routers": g.n, "dense_cells": cells,
           "compacted_dests": int(n_cols),
           "theta_sim": sweep.theta, "theta_analytic": ref.theta,
           "parity_err": parity, "seconds": round(seconds, 3)}
    return row, parity


def pn27_sweep() -> tuple[dict, float]:
    """PN(27) past the dense cap: fused backend + dest compaction.

    ``backend`` is pinned to pallas — the minimal active-set shrink now
    runs before backend selection, so ``auto`` sizes from the
    post-shrink cells (32.1M < SIM_MAX_CELLS) and would pick jax; this
    row exists to time the fused path at scale (module docstring)."""
    g, dem = _points_demand(27)
    cells = g.n * g.max_degree * g.n
    assert cells > SIM_MAX_CELLS  # the row exists to cross the cap
    ref = saturation_report(g, dem, routing="minimal")
    cfg = SimConfig(routing="minimal", backend="pallas")
    sim = Simulator(g, cfg, demand=dem)
    t0 = time.perf_counter()
    sweep = saturation_sweep(g, dem, routing="minimal", config=cfg,
                             loads=np.array([0.90, 1.08]) * ref.theta,
                             steps=40, refine=2, theta_analytic=ref.theta)
    seconds = time.perf_counter() - t0
    parity = abs(sweep.theta - ref.theta) / ref.theta
    row = {"case": "pn27:points:minimal", "backend": sim.backend,
           "routers": g.n, "dense_cells": cells,
           "compacted_dests": len(sim.active),
           "theta_sim": sweep.theta, "theta_analytic": ref.theta,
           "parity_err": parity, "seconds": round(seconds, 3)}
    return row, parity
