"""Regression diff over BENCH JSON artifacts written by benchmarks.run.

Two modes:

* two-file: ``python -m benchmarks.compare BASE.json NEW.json`` — per-entry
  wall-time and parity (``max_rel_err``) deltas, exit 1 when any entry
  regresses past the budgets;
* trajectory: ``python -m benchmarks.compare --dir PATH [--glob 'BENCH_*.json']``
  — diff every consecutive pair of matching files in sorted order (the
  stacked-PR perf trajectory), exit 1 if any hop regresses.

Budgets:

* ``--wall-pct P`` (default 50): an entry fails when its wall time grew by
  more than P percent AND by more than ``--min-seconds`` (default 0.05 s)
  absolute — the floor keeps microsecond-scale closed-form entries, whose
  timings are pure scheduler noise, from tripping the gate.
* ``--err-pct P`` (default 10): an entry fails when ``max_rel_err`` grew by
  more than P percent of the baseline value and by more than ``--min-err``
  (default 1e-6) absolute.  Parity regressions are the loud ones: the
  reproduction drifting from the paper is never timing noise.

Entries present on one side only are reported but never fail the gate
(sections come and go across PRs); a missing/unparsable file does fail it.
CI wiring (scripts/ci.sh) snapshots each BENCH file before regenerating it
and runs the two-file mode against the fresh copy under
``CI_REGRESSION_PCT``.
"""

from __future__ import annotations

import argparse
import glob as globmod
import json
import os
import sys

__all__ = ["load", "diff_entries", "compare_files", "main"]


def load(path: str) -> dict:
    with open(path) as fh:
        payload = json.load(fh)
    if "entries" not in payload:
        raise ValueError(f"{path}: no 'entries' key — not a benchmarks.run "
                         f"artifact")
    return payload


def _by_name(payload: dict) -> dict:
    return {e["name"]: e for e in payload.get("entries", [])}


def diff_entries(base: dict, new: dict, wall_pct: float, err_pct: float,
                 min_seconds: float, min_err: float) -> tuple[list, list]:
    """Compare two payloads entry-by-entry.

    Returns ``(rows, failures)``: ``rows`` is every comparison (for the
    report), ``failures`` the subset that breaks a budget.  A row is
    ``{name, kind, base, new, delta_pct}`` with kind "wall" or "err"."""
    b, n = _by_name(base), _by_name(new)
    rows, failures = [], []
    for name in sorted(set(b) | set(n)):
        if name not in b or name not in n:
            rows.append({"name": name, "kind": "presence",
                         "base": name in b, "new": name in n,
                         "delta_pct": None})
            continue
        eb, en = b[name], n[name]
        sb, sn = float(eb.get("seconds", 0.0)), float(en.get("seconds", 0.0))
        if sb > 0:
            pct = 100.0 * (sn - sb) / sb
            row = {"name": name, "kind": "wall", "base": sb, "new": sn,
                   "delta_pct": pct}
            rows.append(row)
            if pct > wall_pct and (sn - sb) > min_seconds:
                failures.append(row)
        if "max_rel_err" in eb and "max_rel_err" in en:
            vb, vn = float(eb["max_rel_err"]), float(en["max_rel_err"])
            pct = (100.0 * (vn - vb) / vb if vb > 0
                   else (float("inf") if vn > min_err else 0.0))
            row = {"name": name, "kind": "err", "base": vb, "new": vn,
                   "delta_pct": pct}
            rows.append(row)
            if pct > err_pct and (vn - vb) > min_err:
                failures.append(row)
    return rows, failures


def _fmt(row: dict) -> str:
    if row["kind"] == "presence":
        side = "baseline only" if row["base"] else "new only"
        return f"  ~ {row['name']:40s} ({side})"
    unit = "s" if row["kind"] == "wall" else ""
    mark = "!" if row.get("_failed") else " "
    return (f"  {mark} {row['name']:40s} {row['kind']:4s} "
            f"{row['base']:10.4g}{unit} -> {row['new']:10.4g}{unit} "
            f"({row['delta_pct']:+8.1f}%)")


def compare_files(base_path: str, new_path: str, wall_pct: float,
                  err_pct: float, min_seconds: float, min_err: float,
                  verbose: bool = False) -> int:
    base, new = load(base_path), load(new_path)
    rows, failures = diff_entries(base, new, wall_pct, err_pct,
                                  min_seconds, min_err)
    for row in failures:
        row["_failed"] = True
    rb, rn = base.get("git_rev"), new.get("git_rev")
    rev = f" [{rb or '?'} -> {rn or '?'}]" if (rb or rn) else ""
    print(f"compare {os.path.basename(base_path)} -> "
          f"{os.path.basename(new_path)}{rev}: "
          f"{len(failures)} regression(s) "
          f"(budgets: wall +{wall_pct:g}%, err +{err_pct:g}%)")
    shown = rows if verbose else [r for r in rows
                                  if r.get("_failed")
                                  or r["kind"] == "presence"]
    for row in shown:
        print(_fmt(row))
    new_errors = new.get("errors") or []
    if new_errors:
        print(f"  ! {len(new_errors)} crashed section(s) in "
              f"{os.path.basename(new_path)}: "
              f"{[e.get('section') for e in new_errors]}")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="BASE.json NEW.json (two-file mode)")
    ap.add_argument("--dir", default=None, metavar="PATH",
                    help="trajectory mode: diff consecutive sorted files "
                         "matching --glob under PATH")
    ap.add_argument("--glob", default="BENCH_*.json",
                    help="filename pattern for --dir (default BENCH_*.json)")
    ap.add_argument("--wall-pct", type=float, default=50.0, metavar="P",
                    help="fail an entry whose wall time grew >P%% (default "
                         "50; shared-VM timings are noisy — budget "
                         "generously)")
    ap.add_argument("--err-pct", type=float, default=10.0, metavar="P",
                    help="fail an entry whose max_rel_err grew >P%% "
                         "(default 10)")
    ap.add_argument("--min-seconds", type=float, default=0.05, metavar="S",
                    help="absolute wall-growth floor below which the pct "
                         "budget never trips (default 0.05)")
    ap.add_argument("--min-err", type=float, default=1e-6, metavar="E",
                    help="absolute max_rel_err growth floor (default 1e-6)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every entry, not just regressions")
    args = ap.parse_args(argv)

    try:
        if args.dir:
            if args.paths:
                ap.error("--dir and positional paths are mutually exclusive")
            files = sorted(globmod.glob(os.path.join(args.dir, args.glob)))
            if len(files) < 2:
                print(f"# fewer than 2 files match {args.glob!r} under "
                      f"{args.dir} — nothing to compare")
                return 0
            rc = 0
            for a, b in zip(files, files[1:]):
                rc |= compare_files(a, b, args.wall_pct, args.err_pct,
                                    args.min_seconds, args.min_err,
                                    verbose=args.verbose)
            return rc
        if len(args.paths) != 2:
            ap.error("need exactly BASE.json NEW.json (or --dir PATH)")
        return compare_files(args.paths[0], args.paths[1], args.wall_pct,
                             args.err_pct, args.min_seconds, args.min_err,
                             verbose=args.verbose)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"# compare failed: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
