"""Degradation-curve benchmark (BENCH_6): how much theta survives k dead
links, per topology and routing model, plus one live-sim fault parity
row.

``fault_cases`` is the routing-bench zoo (paper families vs torus and
dragonfly); ``fault_one`` runs ``repro.core.faults.degradation_sweep``
at k in {0, 1, 2, 5} uniform link failures under minimal and UGAL and
reports the mean/worst/percentile theta curves.  The recorded
``max_rel_err`` is the largest relative MONOTONICITY violation of the
mean and worst curves — theta-vs-k must be non-increasing (each trial's
fault sets are nested prefixes of one failure order), so any positive
jump is a fault-model bug, failed loudly by ``run.py --err-budget``.

``sim_parity_row`` is the static-vs-dynamic seam in benchmark form: one
seeded 2-link FaultSet on torus2d_8x16, measured as a knee once applied
before t=0 and once injected mid-run (trailing window after the event);
``max_rel_err`` is the relative knee gap, with the analytic degraded
theta recorded alongside.
"""

from __future__ import annotations

import numpy as np

from repro.core import (degraded_report, degradation_sweep, pn_graph,
                        random_faults)

K_FAILURES = (0, 1, 2, 5)
MODELS = ("minimal", "ugal")
TRIALS = 4


def fault_cases():
    from repro.core import demi_pn_graph, dragonfly_graph, oft_graph
    from repro.fabric.model import torus3d_graph
    yield "pn16", pn_graph(16)
    yield "demi_pn16", demi_pn_graph(16)
    yield "oft4", oft_graph(4)
    yield "torus2d_8x16", torus3d_graph(8, 16, 1)
    yield "dragonfly3", dragonfly_graph(3)


def fault_one(g, routing: str):
    """One (topology, routing) degradation curve; returns ``(row, err)``
    where err is the worst relative monotonicity violation."""
    sw = degradation_sweep(g, k_failures=K_FAILURES, trials=TRIALS,
                           pattern="uniform", routing=routing, kind="links",
                           seed=0)
    row = {
        "routing": routing,
        "k_failures": list(sw.k_failures),
        "pristine_theta": sw.pristine_theta,
        "mean_theta": [round(float(v), 6) for v in sw.mean],
        "worst_theta": [round(float(v), 6) for v in sw.worst],
        "best_theta": [round(float(v), 6) for v in sw.best],
        "p10": [round(float(v), 6) for v in sw.bands[10]],
        "p50": [round(float(v), 6) for v in sw.bands[50]],
        "p90": [round(float(v), 6) for v in sw.bands[90]],
        "trials": sw.trials,
    }
    viol = 0.0
    for curve in (sw.mean, sw.worst):
        jump = np.diff(curve)          # must be <= 0 everywhere
        viol = max(viol, float(np.maximum(jump, 0.0).max() / curve[0]))
    return row, viol


def sim_parity_row():
    """Static pre-applied fault vs the same fault mid-run: the measured
    saturation knees must agree once the post-fault transient settles."""
    from repro.fabric.model import torus3d_graph
    from repro.sim import saturation_sweep
    g = torus3d_graph(8, 16, 1)
    fs = random_faults(g, k_links=2, seed=0)
    ref = degraded_report(g, "uniform", fs, routing="minimal").theta
    loads = np.array([0.96, 1.05]) * ref
    static = saturation_sweep(g, "uniform", "minimal", loads=loads, refine=2,
                              theta_analytic=ref, events=[(0, fs)])
    steps = 648                        # event at 40%, window = last third
    dynamic = saturation_sweep(g, "uniform", "minimal", loads=loads,
                               refine=2, theta_analytic=ref, steps=steps,
                               events=[(int(0.4 * steps), fs)])
    gap = abs(static.theta - dynamic.theta) / max(static.theta, 1e-30)
    row = {
        "topology": "torus2d_8x16", "routing": "minimal",
        "faults": fs.label,
        "theta_analytic_degraded": round(float(ref), 6),
        "theta_static": round(float(static.theta), 6),
        "theta_dynamic": round(float(dynamic.theta), 6),
        "knee_gap": round(float(gap), 6),
    }
    return row, float(gap)
