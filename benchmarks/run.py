"""Benchmark harness: one entry per paper table/figure + the traffic and
adversarial-routing sweeps + the fabric planner + the roofline summary.
Prints ``name,us_per_call,derived`` CSV rows where ``derived`` is the
headline validation number for that artifact (max relative error vs. the
paper, or the key reproduced quantity).

``--json PATH`` additionally records per-entry wall time and the numeric
``max_rel_err`` (where the artifact has one) so future changes have a perf
trajectory to regress against, and the run exits nonzero when any entry's
``max_rel_err`` exceeds ``--err-budget`` (default 0.25) — a reproduction
or routing-invariant regression fails CI loudly instead of only being
recorded:

    python -m benchmarks.run --json BENCH_topology.json --only tables
    python -m benchmarks.run --json BENCH_3.json --only routing

Sections degrade gracefully: a crashed section is reported (and recorded
under ``errors`` in the JSON payload) while the remaining sections still
run and the partial artifact is still written — the run then exits
nonzero, so CI fails without losing the data that DID compute.

The arc-load engine behind the tables is selected by REPRO_PERF (see
repro.perf); e.g. ``REPRO_PERF=util_engine=naive`` times the reference
implementation for comparison.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time
import traceback

# bump when the JSON payload layout changes; benchmarks/compare.py reads it
SCHEMA_VERSION = 2


def _git_rev() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else None
    except Exception:
        return None


def _run(records, name, fn, derive, err_of=None):
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    derived = derive(out)
    print(f"{name},{dt * 1e6:.1f},{derived}", flush=True)
    rec = {"name": name, "seconds": round(dt, 6), "derived": derived}
    if err_of is not None:
        rec["max_rel_err"] = float(err_of(out))
    records.append(rec)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write per-entry wall time + max_rel_err as JSON")
    ap.add_argument("--only",
                    choices=["tables", "figures", "traffic", "routing",
                             "placement", "sim", "faults", "kernels",
                             "hlo", "all"],
                    default="all",
                    help="restrict to the paper tables, figures, the "
                         "traffic-pattern saturation sweep, the "
                         "adversarial routing-model table, the "
                         "placement strategy/fragmentation table, the "
                         "simulator parity table (BENCH_5), the "
                         "fault degradation curves (BENCH_6), or the "
                         "fused step kernel rows (BENCH_7); 'hlo' (the "
                         "compile-and-rank op breakdown) runs only when "
                         "named explicitly — it is NOT part of 'all'")
    ap.add_argument("--err-budget", type=float, default=0.25, metavar="E",
                    help="fail (exit 1) when any entry's max_rel_err exceeds "
                         "E instead of only recording it (negative: record "
                         "only)")
    ap.add_argument("--obs", choices=["none", "metrics", "trace"],
                    default="trace",
                    help="per-section repro.obs capture embedded under "
                         "'obs' in the JSON payload (default: trace with "
                         "per-step series capture OFF, so span/counter "
                         "recording stays out of the hot loops)")
    ap.add_argument("--stream", metavar="PATH", default=None,
                    help="append live JSONL telemetry (section boundaries "
                         "+ in-section progress/probe events) to PATH "
                         "while the run is going; tail -f it to watch a "
                         "long benchmark instead of waiting for the JSON")
    args = ap.parse_args(argv)

    records: list[dict] = []
    errors: list[dict] = []
    obs_by_section: dict[str, dict] = {}
    streamer = None
    if args.stream:
        from repro.obs import ObsStreamer
        streamer = ObsStreamer(args.stream)
    print("name,us_per_call,derived")

    def section(name, body):
        """Run one bench section; a crash is reported and recorded but
        never takes the other sections (or the JSON artifact) with it.
        Each section gets its own obs session so the embedded span/metric
        snapshot attributes the work to the section that did it.  The
        shared ``--stream`` file (when open) receives the section
        boundaries directly and rides into each session so in-section
        emitters (sweep probes, Progress) stream through it too."""
        t0 = time.perf_counter()
        if streamer is not None:
            streamer.emit("section", name=name, state="start")
        ok = True
        try:
            if args.obs == "none":
                body()
                return
            from repro import obs
            with obs.session(mode=args.obs, series=False,
                             stream=streamer) as sess:
                try:
                    body()
                finally:
                    snap = sess.snapshot()
                    if snap is not None:
                        obs_by_section[name] = snap
        except Exception as e:
            ok = False
            print(f"# SECTION FAILED [{name}]: {type(e).__name__}: {e}",
                  file=sys.stderr)
            errors.append({"section": name,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()})
        finally:
            if streamer is not None:
                streamer.emit("section", name=name, state="end", ok=ok,
                              seconds=round(time.perf_counter() - t0, 3))

    def run_tables():
        from . import paper_tables as tabs
        for name, fn in tabs.TABLES.items():
            _run(records, name, fn, lambda o: f"max_err={o[1]:.4f}",
                 err_of=lambda o: o[1])

    def run_traffic():
        from . import traffic as traf
        for case_name, g in traf.traffic_cases():
            out = _run(records, f"traffic[{case_name}]",
                       lambda g=g: traf.traffic_one(g),
                       lambda o: (f"min_theta={o[1]['minimal']['min_theta']:.4f}"
                                  f"@{o[1]['minimal']['worst_pattern']}"
                                  f" valiant={o[1]['valiant']['min_theta']:.4f}"))
            records[-1]["patterns"] = out[0]
            records[-1]["summary"] = out[1]

    def run_routing():
        from . import routing_bench as rb
        for case_name, g in rb.routing_cases():
            out = _run(records, f"routing[{case_name}]",
                       lambda g=g: rb.routing_one(g),
                       lambda o: (f"ugal_worst={o[1]['ugal']['min_theta']:.4f}"
                                  f"@{o[1]['ugal']['worst_pattern']}"
                                  f" min={o[1]['minimal']['min_theta']:.4f}"
                                  f" val={o[1]['valiant']['min_theta']:.4f}"),
                       err_of=lambda o: o[2])
            records[-1]["rows"] = out[0]
            records[-1]["worst"] = out[1]

    def run_sim():
        from . import sim_bench as sb
        for case_name, case in sb.sim_cases():
            out = _run(records, f"sim[{case_name}]",
                       lambda case=case: sb.sim_one(case),
                       lambda o: (f"theta={o[0]['theta_sim']:.4f}"
                                  f" analytic={o[0]['theta_analytic']:.4f}"
                                  f" kind={o[0]['kind']}"),
                       err_of=lambda o: o[1])
            records[-1]["row"] = out[0]

    def run_placement():
        from . import placement_bench as pb
        for case_name, g, mesh, axes, d0, exp in pb.placement_cases():
            out = _run(records, f"placement[{case_name}]",
                       lambda g=g, mesh=mesh, axes=axes, d0=d0, exp=exp:
                           pb.placement_one(g, mesh, axes, d0, exp),
                       lambda o: (f"ep_best={o[1]['ep_heavy']['best']}"
                                  f"@{o[1]['ep_heavy']['best_theta']:.4f}"
                                  f" lin={o[1]['ep_heavy']['linear_theta']:.4f}"
                                  f" frag={o[1]['fragmentation']['best']}"),
                       err_of=lambda o: o[2])
            records[-1]["rows"] = out[0]
            records[-1]["summary"] = out[1]

    def run_faults():
        from . import fault_bench as fb
        for case_name, g in fb.fault_cases():
            for routing in fb.MODELS:
                out = _run(records, f"faults[{case_name}:{routing}]",
                           lambda g=g, routing=routing:
                               fb.fault_one(g, routing),
                           lambda o: (f"theta_k={','.join(f'{v:.3f}' for v in o[0]['mean_theta'])}"
                                      f" worst_k5={o[0]['worst_theta'][-1]:.3f}"),
                           err_of=lambda o: o[1])
                records[-1]["row"] = out[0]
        out = _run(records, "faults[sim_parity:torus2d_8x16]",
                   fb.sim_parity_row,
                   lambda o: (f"static={o[0]['theta_static']:.4f}"
                              f" dynamic={o[0]['theta_dynamic']:.4f}"
                              f" gap={o[0]['knee_gap']:.4f}"),
                   err_of=lambda o: o[1])
        records[-1]["row"] = out[0]

    def run_kernels():
        from . import kernel_bench as kb
        out = _run(records, "kernels[pn16:step_timing]", kb.step_timing,
                   lambda o: (f"numpy={o[0]['ms_per_step']['numpy']:.1f}ms"
                              f" jax={o[0]['ms_per_step']['jax']:.1f}ms"
                              f" pallas={o[0]['ms_per_step']['pallas']:.1f}ms"),
                   err_of=lambda o: o[1])
        records[-1]["row"] = out[0]
        out = _run(records, "kernels[pn16:sweep]", kb.pn16_sweep,
                   lambda o: (f"theta={o[0]['theta_sim']:.4f}"
                              f" analytic={o[0]['theta_analytic']:.4f}"
                              f" speedup={o[0]['speedup']:.1f}x"),
                   err_of=lambda o: o[1])
        records[-1]["row"] = out[0]
        out = _run(records, "kernels[pn16:ugal_compacted]",
                   kb.pn16_ugal_compacted,
                   lambda o: (f"knee={o[0]['theta_sim']:.4f}"
                              f" analytic={o[0]['theta_analytic']:.4f}"
                              f" cols={o[0]['compacted_dests']}/{o[0]['dense_dests']}"
                              f" speedup={o[0]['speedup']:.1f}x"),
                   err_of=lambda o: o[1])
        records[-1]["row"] = out[0]
        out = _run(records, "kernels[pn27:ugal]", kb.pn27_ugal,
                   lambda o: (f"theta={o[0]['theta_sim']:.4f}"
                              f" analytic={o[0]['theta_analytic']:.4f}"
                              f" cells={o[0]['dense_cells']}"
                              f" dests={o[0]['compacted_dests']}"),
                   err_of=lambda o: o[1])
        records[-1]["row"] = out[0]
        out = _run(records, "kernels[pn27:sweep]", kb.pn27_sweep,
                   lambda o: (f"theta={o[0]['theta_sim']:.4f}"
                              f" analytic={o[0]['theta_analytic']:.4f}"
                              f" cells={o[0]['dense_cells']}"
                              f" backend={o[0]['backend']}"),
                   err_of=lambda o: o[1])
        records[-1]["row"] = out[0]

    def run_figures():
        from . import paper_figures as figs
        _run(records, "fig5_mms_vs_moore", figs.fig5,
             lambda o: f"tail_vs_8/9_err={o[1]:.4f}", err_of=lambda o: o[1])
        _run(records, "fig6_mms_utilization", figs.fig6,
             lambda o: f"tail_vs_8/9_err={o[1]:.4f}", err_of=lambda o: o[1])
        _run(records, "fig7_cost_vs_bound", figs.fig7,
             lambda o: f"bound_violation={o[1]:.4f}", err_of=lambda o: o[1])
        _run(records, "fig8_scalability", figs.fig8, lambda o: f"rows={len(o[0])}")
        _run(records, "fig9_pn_vs_slimfly", figs.fig9,
             lambda o: f"demi_pn_worse_than_sf_cases={o[1]:.0f}")

    def run_hlo():
        # compile-and-rank op breakdown for the smallest arch; explicit
        # --only hlo opt-in (a full XLA compile is far slower than any
        # paper table, so it never rides under "all")
        from . import hlo_breakdown as hb
        out = _run(records, "hlo[smollm-135m:train_4k]",
                   lambda: hb.breakdown("smollm-135m", "train_4k", top=10),
                   lambda o: (f"flops={o['flops_per_device']:.3e}"
                              f" kinds={len(o['by_kind'])}"
                              f" collectives={len(o['collectives'])}"))
        records[-1]["row"] = out

    sections = [("tables", run_tables), ("traffic", run_traffic),
                ("routing", run_routing), ("sim", run_sim),
                ("placement", run_placement), ("faults", run_faults),
                ("kernels", run_kernels), ("figures", run_figures),
                ("hlo", run_hlo)]
    for name, body in sections:
        if args.only == name or (args.only == "all" and name != "hlo"):
            section(name, body)

    if args.only == "all":
        # fabric planner on a real dry-run profile when available
        try:
            from repro.fabric import StepProfile, plan

            from .roofline import load_records
            recs = [r for r in load_records() if r.get("status") == "ok"
                    and r.get("shape") == "train_4k"]
            if recs:
                rec = max(recs, key=lambda r: r["collective_bytes_per_device"]
                          .get("total", 0))
                prof = StepProfile.from_dryrun(rec)

                def _best(rows):
                    # paper's Section-5 rule: cheapest fabric within 5% of the
                    # best step time (all candidates are full-bisection sized)
                    t0 = rows[0]["step_comm_ms"]
                    near = [r for r in rows if r["step_comm_ms"] <= 1.05 * t0]
                    c = min(near, key=lambda r: r["usd_per_node"])
                    return f"best={c['fabric']}@{c['usd_per_node']}$"
                _run(records, f"fabric_planner[{rec['arch']}]",
                     lambda: plan(prof, min_terminals=10000), _best)
        except Exception as e:  # planner needs dry-run artifacts
            print(f"fabric_planner,0,unavailable({type(e).__name__})")

        # roofline summary over whatever cells have been dry-run
        try:
            from .roofline import roofline_table
            rows, skipped, errors = roofline_table()
            n_dom = {}
            for r in rows:
                n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
            print(f"roofline_summary,0,cells={len(rows)} skipped={len(skipped)} "
                  f"errors={len(errors)} dominant={n_dom}")
        except Exception as e:
            print(f"roofline_summary,0,unavailable({type(e).__name__})")

    if args.json:
        from repro.perf import flags
        payload = {
            "schema_version": SCHEMA_VERSION,
            "git_rev": _git_rev(),
            "machine": platform.machine(),
            "python": platform.python_version(),
            "util_engine": flags().util_engine,
            "total_seconds": round(sum(r["seconds"] for r in records), 6),
            "entries": records,
            "errors": errors,
        }
        if obs_by_section:
            payload["obs"] = obs_by_section
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {args.json} ({len(records)} entries, "
              f"{len(errors)} section errors)")

    if streamer is not None:
        streamer.emit("done", entries=len(records), errors=len(errors))
        streamer.close()

    failed = False
    if args.err_budget >= 0:
        bad = [r for r in records
               if r.get("max_rel_err", 0.0) > args.err_budget]
        if bad:
            names = {r["name"]: r["max_rel_err"] for r in bad}
            print(f"# FAIL: max_rel_err over budget {args.err_budget}: "
                  f"{names}", file=sys.stderr)
            failed = True
    if errors:
        print(f"# FAIL: {len(errors)} section(s) crashed: "
              f"{[e['section'] for e in errors]}", file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
