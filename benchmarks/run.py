"""Benchmark harness: one entry per paper table/figure + the fabric planner
+ the roofline summary.  Prints ``name,us_per_call,derived`` CSV rows where
``derived`` is the headline validation number for that artifact (max
relative error vs. the paper, or the key reproduced quantity).
"""

from __future__ import annotations

import sys
import time


def _run(name, fn, derive):
    t0 = time.perf_counter()
    out = fn()
    dt = (time.perf_counter() - t0) * 1e6
    print(f"{name},{dt:.1f},{derive(out)}", flush=True)
    return out


def main() -> None:
    from . import paper_figures as figs
    from . import paper_tables as tabs

    print("name,us_per_call,derived")
    _run("table2_topological_params", tabs.table2, lambda o: f"max_err={o[1]:.4f}")
    _run("table3_structural_params", tabs.table3, lambda o: f"max_err={o[1]:.4f}")
    _run("table4_10k_nodes", tabs.table4, lambda o: f"max_err={o[1]:.4f}")
    _run("table5_25k_nodes", tabs.table5, lambda o: f"max_err={o[1]:.4f}")
    _run("table6_indirect", tabs.table6, lambda o: f"max_err={o[1]:.4f}")
    _run("fig5_mms_vs_moore", figs.fig5, lambda o: f"tail_vs_8/9_err={o[1]:.4f}")
    _run("fig6_mms_utilization", figs.fig6, lambda o: f"tail_vs_8/9_err={o[1]:.4f}")
    _run("fig7_cost_vs_bound", figs.fig7, lambda o: f"bound_violation={o[1]:.4f}")
    _run("fig8_scalability", figs.fig8, lambda o: f"rows={len(o[0])}")
    _run("fig9_pn_vs_slimfly", figs.fig9,
         lambda o: f"demi_pn_worse_than_sf_cases={o[1]:.0f}")

    # fabric planner on a real dry-run profile when available
    try:
        from repro.fabric import StepProfile, plan
        from .roofline import load_records
        recs = [r for r in load_records() if r.get("status") == "ok"
                and r.get("shape") == "train_4k"]
        if recs:
            rec = max(recs, key=lambda r: r["collective_bytes_per_device"]
                      .get("total", 0))
            prof = StepProfile.from_dryrun(rec)

            def _best(rows):
                # paper's Section-5 rule: cheapest fabric within 5% of the
                # best step time (all candidates are full-bisection sized)
                t0 = rows[0]["step_comm_ms"]
                near = [r for r in rows if r["step_comm_ms"] <= 1.05 * t0]
                c = min(near, key=lambda r: r["usd_per_node"])
                return f"best={c['fabric']}@{c['usd_per_node']}$"
            _run(f"fabric_planner[{rec['arch']}]",
                 lambda: plan(prof, min_terminals=10000), _best)
    except Exception as e:  # planner needs dry-run artifacts
        print(f"fabric_planner,0,unavailable({type(e).__name__})")

    # roofline summary over whatever cells have been dry-run
    try:
        from .roofline import roofline_table
        rows, skipped, errors = roofline_table()
        n_dom = {}
        for r in rows:
            n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
        print(f"roofline_summary,0,cells={len(rows)} skipped={len(skipped)} "
              f"errors={len(errors)} dominant={n_dom}")
    except Exception as e:
        print(f"roofline_summary,0,unavailable({type(e).__name__})")


if __name__ == "__main__":
    main()
