"""HLO breakdown for §Perf hypothesis formation: compile ONE unrolled layer
(the dry-run probe config) of an (arch × shape) cell and rank ops by result
bytes, with collectives broken out by shape — the 'profile' the hillclimb
iterates on (no real-TPU timings exist in this container).

Usage:
  PYTHONPATH=src python -m benchmarks.hlo_breakdown --arch deepseek-v3-671b \
      --shape train_4k [--top 25] [--layers 1]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re


OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"([a-z0-9-]+)\(")


def main():
    from repro.launch.dryrun import (SHAPE_RE, DTYPE_BYTES, _compile_metrics,
                                     _shape_bytes, _lower_any)
    from repro.configs import SHAPES, get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.models.transformer import layer_plan

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--layers", type=int, default=1, help="unrolled periods")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    plan = layer_plan(cfg)
    probe = cfg.replace(n_layers=plan.prefix + args.layers * plan.period,
                        scan_layers=False)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        lowered = _lower_any(probe, SHAPES[args.shape], mesh)
        compiled = lowered.compile()
    text = compiled.as_text()

    by_kind_bytes = collections.Counter()
    by_kind_count = collections.Counter()
    biggest = []
    for line in text.splitlines():
        m = OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if kind in ("parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast"):
            continue
        nbytes = _shape_bytes(shape_str)
        by_kind_bytes[kind] += nbytes
        by_kind_count[kind] += 1
        biggest.append((nbytes, kind, shape_str.strip()[:90]))

    cost = compiled.cost_analysis()
    print(f"# {args.arch} x {args.shape} probe ({args.layers} period(s), "
          f"mesh {'2x16x16' if args.multi_pod else '16x16'})")
    print(f"flops/device={cost.get('flops', 0):.4e}  "
          f"bytes/device={cost.get('bytes accessed', 0):.4e}")
    print("\n## result bytes by op kind (per device)")
    for kind, v in by_kind_bytes.most_common(args.top):
        print(f"{kind:26s} {v/2**30:10.3f} GiB  x{by_kind_count[kind]}")
    print("\n## largest single ops")
    for nbytes, kind, shape in sorted(biggest, reverse=True)[: args.top]:
        print(f"{nbytes/2**30:10.3f} GiB  {kind:22s} {shape}")
    print("\n## collectives")
    for nbytes, kind, shape in sorted(
            (b for b in biggest if "all-" in b[1] or "collective" in b[1]
             or "reduce-scatter" in b[1]), reverse=True)[: args.top]:
        print(f"{nbytes/2**30:10.3f} GiB  {kind:22s} {shape}")


if __name__ == "__main__":
    main()
