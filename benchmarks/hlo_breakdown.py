"""HLO breakdown for §Perf hypothesis formation: compile ONE unrolled layer
(the dry-run probe config) of an (arch × shape) cell and rank ops by result
bytes, with collectives broken out by shape — the 'profile' the hillclimb
iterates on (no real-TPU timings exist in this container).

Usage:
  PYTHONPATH=src python -m benchmarks.hlo_breakdown --arch deepseek-v3-671b \
      --shape train_4k [--top 25] [--layers 1]

``breakdown()`` is the library face (benchmarks.run wires it in as the
``hlo`` section): it returns the ranked tables as a JSON-safe dict and
leaves the printing to :func:`main`.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re


OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"([a-z0-9-]+)\(")

# bookkeeping ops whose result bytes say nothing about data movement
_SKIP_KINDS = ("parameter", "constant", "tuple", "get-tuple-element",
               "bitcast")


def breakdown(arch: str, shape: str = "train_4k", top: int = 25,
              layers: int = 1, multi_pod: bool = False) -> dict:
    """Compile the (arch × shape) probe layer and rank its HLO ops by
    result bytes.  Returns a JSON-safe dict:

    ``{"arch", "shape", "mesh", "flops_per_device",
    "bytes_per_device", "by_kind": [{"kind", "bytes", "count"}, ...],
    "largest": [{"bytes", "kind", "shape"}, ...],
    "collectives": [...same rows...]}``
    """
    from repro.configs import SHAPES, get_arch
    from repro.launch.dryrun import _lower_any, _shape_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.models.transformer import layer_plan

    cfg = get_arch(arch)
    plan = layer_plan(cfg)
    probe = cfg.replace(n_layers=plan.prefix + layers * plan.period,
                        scan_layers=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        lowered = _lower_any(probe, SHAPES[shape], mesh)
        compiled = lowered.compile()
    text = compiled.as_text()

    by_kind_bytes = collections.Counter()
    by_kind_count = collections.Counter()
    biggest = []
    for line in text.splitlines():
        m = OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if kind in _SKIP_KINDS:
            continue
        nbytes = _shape_bytes(shape_str)
        by_kind_bytes[kind] += nbytes
        by_kind_count[kind] += 1
        biggest.append((nbytes, kind, shape_str.strip()[:90]))

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    if cost is None:
        cost = {}
    rows = [{"bytes": int(n), "kind": k, "shape": s}
            for n, k, s in sorted(biggest, reverse=True)[:top]]
    coll = [{"bytes": int(n), "kind": k, "shape": s}
            for n, k, s in sorted(
                (b for b in biggest if "all-" in b[1] or "collective" in b[1]
                 or "reduce-scatter" in b[1]), reverse=True)[:top]]
    return {
        "arch": arch,
        "shape": shape,
        "layers": int(layers),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "flops_per_device": float(cost.get("flops", 0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0)),
        "by_kind": [{"kind": k, "bytes": int(v),
                     "count": int(by_kind_count[k])}
                    for k, v in by_kind_bytes.most_common(top)],
        "largest": rows,
        "collectives": coll,
    }


def main():
    from repro.configs import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--layers", type=int, default=1, help="unrolled periods")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    out = breakdown(args.arch, args.shape, top=args.top, layers=args.layers,
                    multi_pod=args.multi_pod)
    print(f"# {out['arch']} x {out['shape']} probe ({out['layers']} "
          f"period(s), mesh {out['mesh']})")
    print(f"flops/device={out['flops_per_device']:.4e}  "
          f"bytes/device={out['bytes_per_device']:.4e}")
    print("\n## result bytes by op kind (per device)")
    for row in out["by_kind"]:
        print(f"{row['kind']:26s} {row['bytes']/2**30:10.3f} GiB  "
              f"x{row['count']}")
    print("\n## largest single ops")
    for row in out["largest"]:
        print(f"{row['bytes']/2**30:10.3f} GiB  {row['kind']:22s} "
              f"{row['shape']}")
    print("\n## collectives")
    for row in out["collectives"]:
        print(f"{row['bytes']/2**30:10.3f} GiB  {row['kind']:22s} "
              f"{row['shape']}")


if __name__ == "__main__":
    main()
