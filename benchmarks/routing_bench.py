"""Adversarial routing-model benchmarks: the PolarFly-style comparison.

For each case-study topology (the paper's PN / demi-PN / OFT against the
torus and dragonfly reference points) run the adversarial harness
(repro.core.adversary): theta for every named adversary pattern plus the
worst sampled permutation, under minimal, Valiant, and UGAL routing.
``benchmarks.run --only routing`` serializes the table into BENCH_3.json.

The headline number per topology is UGAL's worst-case theta — the
throughput guarantee an adaptive router extracts, which neither pure
bracket reports: minimal collapses on structured adversaries, Valiant
halves uniform throughput, and UGAL's blend sits at or above both
everywhere.  The 8x16 torus case is the textbook demonstration: on
tornado its blend optimum is interior (alpha ~0.40), strictly above both
pure routings, while on the paper's arc-transitive PN the blend never
needs the detour (alpha = 1 on uniform, theta_ugal == theta_minimal).

``max_rel_err`` per topology checks two exact identities of the blend —
theta_ugal >= max(theta_minimal, theta_valiant) on every pattern, and
theta_ugal == theta_minimal on uniform — so a regression in the routing
subsystem fails the benchmark run loudly (see run.py --err-budget).
"""

from __future__ import annotations

from repro.core import demi_pn_graph, oft_graph, pn_graph
from repro.core.adversary import (DEFAULT_ADVERSARY_PATTERNS, DEFAULT_MODELS,
                                  adversarial_report)
from repro.core.reference import dragonfly_graph
from repro.fabric.model import torus3d_graph

N_RANDOM = 8  # sampled permutations per (topology, model) worst-case search


def routing_cases():
    return [
        ("pn16", pn_graph(16)),
        ("demi_pn16", demi_pn_graph(16)),
        ("oft4", oft_graph(4)),            # leaf-restricted (Section 6)
        ("torus3d_444", torus3d_graph(4, 4, 4)),
        ("torus2d_8x16", torus3d_graph(8, 16, 1)),  # tornado's home ground
        ("dragonfly3", dragonfly_graph(3)),
    ]


def routing_one(g, patterns=DEFAULT_ADVERSARY_PATTERNS,
                models=DEFAULT_MODELS, n_random=N_RANDOM):
    """(rows, worst, max_rel_err) for one topology.

    ``max_rel_err`` is the largest violation of the blend identities:
    how far theta_ugal falls below max(theta_minimal, theta_valiant) on
    any pattern (must be >= 0 up to round-off) and how far uniform
    theta_ugal drifts from theta_minimal (must be equal — alpha = 1)."""
    rows, worst = adversarial_report(g, patterns=patterns, models=models,
                                     n_random=n_random)
    by_pattern: dict[str, dict[str, float]] = {}
    for r in rows:
        by_pattern.setdefault(r["pattern"], {})[r["routing"]] = r["theta"]
    err = 0.0
    for pattern, cells in by_pattern.items():
        if "ugal" not in cells:
            continue
        pure = [v for k, v in cells.items() if k in ("minimal", "valiant")]
        if pure:
            err = max(err, (max(pure) - cells["ugal"]) / max(pure))
        if pattern == "uniform" and "minimal" in cells:
            err = max(err, abs(cells["ugal"] - cells["minimal"])
                      / cells["minimal"])
    return rows, worst, err
