"""Reproduction of the paper's Tables 2–6, validated against the published
values.  Each function returns (rows, max_rel_err_vs_paper)."""

from __future__ import annotations

import numpy as np

from repro.core import (
    DirectNetworkSpec, cable_split, complete_bipartite_graph, complete_graph,
    demi_pn_graph, dollars_per_node, dragonfly_graph, electrical_groups,
    hamming_graph, hypercube_graph, mlfm_graph, mms_graph, network_summary,
    oft_graph, pn_graph, turan_graph, utilization, watts_per_node,
)
from repro.core.reference import dragonfly_canonical_stats


# ---------------------------------------------------------------------------
# Table 2: diameter / lim k̄ / lim u per family — verified on instances
# ---------------------------------------------------------------------------

TABLE2_EXPECT = {
    # family: (k, lim kbar, lim u, instance builder, parameter, tolerance)
    "complete": (1, 1.0, 1.0),
    "turan_r3": (2, 4 / 3, 1.0),
    "bipartite": (2, 1.5, 1.0),
    "hamming2": (2, 2.0, 1.0),
    "demi_pn": (2, 2.0, 1.0),
    "mms": (2, 2.0, 8 / 9),
    "pn": (3, 2.5, 1.0),
    "dragonfly": (3, 3.0, 1.0),
    "hamming3": (3, 3.0, 1.0),
}


def table2():
    rows, errs = [], []
    cases = [
        ("complete", complete_graph(24), None),
        ("turan_r3", turan_graph(24, 3), None),
        ("bipartite", complete_bipartite_graph(12), None),
        ("hamming2", hamming_graph(16, 2), None),
        ("demi_pn", demi_pn_graph(16), None),
        ("mms", mms_graph(17), None),
        ("pn", pn_graph(13), None),
        ("dragonfly", dragonfly_graph(6), dragonfly_canonical_stats(6)),
        ("hamming3", hamming_graph(8, 3), None),
    ]
    for name, g, canonical in cases:
        k_exp, kbar_lim, u_lim = TABLE2_EXPECT[name]
        if canonical is not None:
            kbar, u = canonical
            diam = g.diameter([0])
        else:
            rep = utilization(g)
            kbar, u, diam = rep.kbar, rep.u, rep.diameter
        # finite instances approach the limit from below/above; check trend
        kbar_err = abs(kbar - kbar_lim) / kbar_lim
        u_err = abs(u - u_lim) / u_lim
        rows.append({"family": name, "N": g.n, "diameter": diam,
                     "kbar": round(kbar, 4), "kbar_lim": kbar_lim,
                     "u": round(u, 4), "u_lim": round(u_lim, 4)})
        assert diam == k_exp, (name, diam, k_exp)
        errs.append(u_err if name == "mms" else max(kbar_err, u_err))
    # limits are asymptotic: instances must be within 20% and diameters exact
    return rows, max(errs)


# ---------------------------------------------------------------------------
# Table 3: structural parameters (closed forms) vs constructed graphs
# ---------------------------------------------------------------------------


def table3():
    rows, errs = [], []
    checks = [
        ("demi_pn", demi_pn_graph(8), 8, lambda q: (q * q + q + 1, q + 1)),
        ("pn", pn_graph(8), 8, lambda q: (2 * (q * q + q + 1), q + 1)),
        ("mms", mms_graph(13), 13, lambda q: (2 * q * q, (3 * q - 1) // 2)),  # eps=+1
        ("dragonfly", dragonfly_graph(4), 4, lambda h: (4 * h**3 + 2 * h, 3 * h - 1)),
        ("hamming2", hamming_graph(9, 2), 9, lambda n: (n * n, 2 * (n - 1))),
        ("hypercube", hypercube_graph(7), 7, lambda n: (2**n, n)),
        ("bipartite", complete_bipartite_graph(9), 9, lambda n: (2 * n, n)),
    ]
    for name, g, p, formula in checks:
        n_exp, deg_exp = formula(p)
        rows.append({"family": name, "param": p, "N": g.n, "N_formula": n_exp,
                     "degree": g.max_degree, "degree_formula": deg_exp})
        errs.append(0.0 if (g.n == n_exp and g.max_degree == deg_exp) else 1.0)
    return rows, max(errs)


# ---------------------------------------------------------------------------
# Tables 4 & 5: cases of use (~10k and ~25k compute nodes)
# ---------------------------------------------------------------------------

PAPER_T4 = {  # name: (T, R, N, Δ0, subscription, cost$, W)
    "Hamming K22^2": (10648, 64, 484, 22, 1.002, 1145.41, 8.15),
    "demi-PN(27)": (10598, 42, 757, 14, 0.999, 1282.59, 8.40),
    "SF MMS(19)": (9386, 42, 722, 13, 0.991, 1294.51, 9.05),
    "PN(23)": (9954, 33, 1106, 9, 0.921, 1546.83, 10.27),
    "dragonfly(7)": (9702, 27, 1386, 7, 0.994, 1404.42, 10.80),
}

PAPER_T5 = {
    "Hamming K29^2": (24389, 85, 841, 29, 1.001, 1237.43, 8.21),
    "demi-PN(37)": (26733, 57, 1407, 19, 0.999, 1314.29, 8.40),
    "SF MMS(27)": (26244, 59, 1458, 18, 0.976, 1344.11, 9.18),
    "PN(31)": (25818, 45, 1986, 13, 1.003, 1497.77, 9.70),
    "dragonfly(9)": (26406, 35, 2934, 9, 0.996, 1457.39, 10.89),
}


def _case_rows(cases, paper):
    rows, errs = [], []
    for name, g, delta0, kbar, u in cases:
        labels = electrical_groups(g, delta0)
        ne, no = cable_split(g, labels)
        spec = DirectNetworkSpec(
            name=name, terminals=int(round(g.n * delta0)),
            radix=int(round(g.max_degree + delta0)), routers=g.n,
            degree=g.max_degree, terminals_per_router=delta0, kbar=kbar, u=u,
            electrical_cables=ne, optical_cables=no)
        row = network_summary(spec)
        pt = paper[name]
        row["paper_cost"] = pt[5]
        row["paper_watts"] = pt[6]
        rows.append(row)
        # exact structural + power matches; $ depends on the cable layout —
        # our greedy grouping is allowed to beat the paper's
        assert (row["T"], row["R"], row["N"]) == pt[:3], (name, row)
        errs.append(abs(row["power_per_node_w"] - pt[6]) / pt[6])
        errs.append(abs(row["subscription"] - pt[4]) / pt[4])
        errs.append(max(0.0, (row["cost_per_node_usd"] - pt[5]) / pt[5]))
    return rows, max(errs)


def table4():
    g_h = hamming_graph(22, 2)
    g_d = demi_pn_graph(27)
    g_m = mms_graph(19)
    g_p = pn_graph(23)
    g_f = dragonfly_graph(7)
    rep_m = utilization(g_m)
    kb_f, u_f = dragonfly_canonical_stats(7)
    cases = [
        ("Hamming K22^2", g_h, 22, g_h.average_distance([0]), 1.0),
        ("demi-PN(27)", g_d, 14, 2 - 28 / g_d.n, (2 * 729 + 28) / (2 * 27 * 28)),
        ("SF MMS(19)", g_m, 13, rep_m.kbar, rep_m.u),
        ("PN(23)", g_p, 9, (5 * 529 + 69 + 1) / (2 * 529 + 46 + 1), 1.0),
        ("dragonfly(7)", g_f, 7, kb_f, u_f),
    ]
    return _case_rows(cases, PAPER_T4)


def table5():
    g_h = hamming_graph(29, 2)
    g_d = demi_pn_graph(37)
    g_m = mms_graph(27)
    g_p = pn_graph(31)
    g_f = dragonfly_graph(9)
    rep_m = utilization(g_m)
    kb_f, u_f = dragonfly_canonical_stats(9)
    q = 37
    cases = [
        ("Hamming K29^2", g_h, 29, g_h.average_distance([0]), 1.0),
        ("demi-PN(37)", g_d, 19, 2 - (q + 1) / g_d.n,
         (2 * q * q + q + 1) / (2 * q * (q + 1))),
        ("SF MMS(27)", g_m, 18, rep_m.kbar, rep_m.u),
        ("PN(31)", g_p, 13, (5 * 31 * 31 + 3 * 31 + 1) / (2 * 31 * 31 + 2 * 31 + 1), 1.0),
        ("dragonfly(9)", g_f, 9, kb_f, u_f),
    ]
    return _case_rows(cases, PAPER_T5)


# ---------------------------------------------------------------------------
# Table 6: indirect networks (MLFM / OFT)
# ---------------------------------------------------------------------------

PAPER_T6 = {
    "MLFM(22)": (9702, 42, 693, 21, 9702, 1297.18, 8.4),
    "MLFM(30)": (25230, 58, 1305, 29, 25230, 1321.76, 8.4),
    "OFT(16)": (9282, 34, 819, 17, 9282, 1282.19, 8.4),
    "OFT(23)": (26544, 48, 1659, 24, 26544, 1312.14, 8.4),
}


def table6():
    rows, errs = [], []
    for name, builder, p, delta0 in [
            ("MLFM(22)", mlfm_graph, 22, 21), ("MLFM(30)", mlfm_graph, 30, 29),
            ("OFT(16)", oft_graph, 16, 17), ("OFT(23)", oft_graph, 23, 24)]:
        g = builder(p)
        leaf = g.meta["leaf_mask"]
        n_leaf = int(leaf.sum())
        spec = DirectNetworkSpec(
            name=name, terminals=n_leaf * delta0,
            radix=int(g.degrees.max()), routers=g.n, degree=int(g.degrees.max()),
            terminals_per_router=delta0, kbar=2.0, u=1.0,
            electrical_cables=0, optical_cables=g.num_edges, indirect=True)
        row = {"name": name, "T": spec.terminals, "R": spec.radix,
               "N": spec.routers, "delta0": delta0, "cables": g.num_edges,
               "cost_per_node_usd": round(dollars_per_node(spec), 2),
               "power_per_node_w": round(watts_per_node(spec), 2)}
        pt = PAPER_T6[name]
        rows.append(row)
        assert (row["T"], row["R"], row["N"], row["delta0"], row["cables"]) == pt[:5], (name, row, pt)
        errs.append(abs(row["cost_per_node_usd"] - pt[5]) / pt[5])
        errs.append(abs(row["power_per_node_w"] - pt[6]) / pt[6])
    return rows, max(errs)


# name -> builder, in paper order; benchmarks/run.py iterates this for its
# CSV/JSON output, so new tables only need an entry here
TABLES = {
    "table2_topological_params": table2,
    "table3_structural_params": table3,
    "table4_10k_nodes": table4,
    "table5_25k_nodes": table5,
    "table6_indirect": table6,
}
